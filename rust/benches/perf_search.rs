//! §Perf: architecture/mapping co-search wall clock — the PR 9
//! acceptance gate (DESIGN.md §15).
//!
//! Baseline ("isolated"): the fixed 32-point grid scored serially, one
//! point at a time, each with its OWN fresh `PlanCache`, `MapperCache`
//! and mapper seed — i.e. a sweep that treats every config as a
//! standalone cold run, the way `voltra suite --config ...` in a shell
//! loop would.
//!
//! Shipped ("shared"): `search::run_grid` — the work-stealing search
//! pool over ONE structurally-keyed cache stack. Grid points that share
//! a tile-structural class (32 points collapse to 16) reuse each
//! other's tile simulations; points sharing a mapper class (16) reuse
//! resolved mappings; each pool worker's `IncrementalMapper` seed
//! persists across the adjacent points it claims.
//!
//! Both sides run the identical per-point scoring (plan the full
//! eight-workload suite, execute, fold energy/area), so the measured
//! ratio isolates exactly what this PR added: structural cache sharing
//! plus the parallel search pool. The gate is 4x.

#[path = "common.rs"]
mod common;

use voltra::search;
use voltra::tiling::mapper::MapperCache;
use voltra::tiling::IncrementalMapper;
use voltra::workloads::evaluation_suite;
use voltra::PlanCache;

fn main() {
    common::header("§Perf — 32-point co-search: isolated serial vs shared-cache pool");
    let grid = search::full_grid();
    let suite = evaluation_suite();
    let threads = search::default_threads();

    let isolated = common::time(2, || {
        let mut points = Vec::with_capacity(grid.len());
        for (label, cfg) in &grid {
            let plans = PlanCache::new();
            let mappers = MapperCache::new();
            let mut im = IncrementalMapper::new(&mappers);
            points.push(search::score_config(label, cfg, &suite, &plans, &mut im));
        }
        std::hint::black_box(points);
    });
    common::show("search x32, isolated caches (serial)", 2, isolated);

    let shared = common::time(3, || {
        std::hint::black_box(search::run_grid(&grid, threads));
    });
    common::show(
        &format!("search x32, shared caches ({threads} thr pool)"),
        3,
        shared,
    );

    // Telemetry from one more run: the structural collapse the speedup
    // comes from.
    let r = search::run_grid(&grid, threads);
    let s = r.stats;
    println!(
        "structural sharing: {} tile classes / {} mapper classes across {} configs; \
         tiles {:.1}% hit rate, mapper {} hits / {} misses",
        s.tile_classes,
        s.mapper_classes,
        r.points.len(),
        100.0 * s.tiles.hit_rate(),
        s.mapper.hits,
        s.mapper.misses,
    );

    common::rule();
    let (iso_mean, _, _) = isolated;
    let (shr_mean, _, _) = shared;
    let speedup = iso_mean / shr_mean;
    println!(
        "shared-cache parallel search is {speedup:.1}x faster than the isolated \
         serial sweep ({threads} workers; floor 4x)"
    );
    assert!(
        speedup >= 4.0,
        "PR 9 acceptance: shared-cache parallel search must be >= 4x faster than \
         the isolated-cache serial baseline on the fixed 32-point grid \
         (got {speedup:.2}x)"
    );
}
