//! §Perf: whole-suite cold-plan wall clock — the PR 6 acceptance gate.
//!
//! Baseline ("walked"): every layer of all eight Fig. 6 workloads
//! planned sequentially against the per-cycle reference walker
//! (`simulate_tile_reference`), i.e. planning as it stood before the
//! steady-state fast path and the parallel layer compile landed.
//!
//! Shipped ("fast"): `plan::build_parallel` — the exact cold path a
//! `PlanCache` miss takes — over a fresh `SharedTileCache`, with the
//! row-recurrence fast path dispatching every eligible tile
//! (DESIGN.md §12).
//!
//! Both sides resolve mappings through warm, persistent mapper caches
//! (the process-wide `MapperCache` predates this PR), and both rebuild
//! all tile/plan state from scratch every iteration — that is the
//! "cold plan". The measured ratio therefore isolates what PR 6 added,
//! and must be at least 5x.

#[path = "common.rs"]
mod common;

use std::collections::HashMap;

use voltra::config::ChipConfig;
use voltra::coordinator::{SharedTileCache, SimCache};
use voltra::metrics::TileMetrics;
use voltra::plan::{self, planner, residency};
use voltra::sim::{simulate_tile_reference, TileSpec};
use voltra::tiling::mapper::MapperCache;
use voltra::tiling::IncrementalMapper;
use voltra::workloads::evaluation_suite;

/// The pre-fast-path tile store: memoized per-cycle reference walks
/// (same memoization as `TileCache`, walked simulation instead of the
/// dispatcher — so the comparison is fast path vs walk, not cache vs
/// no cache).
struct RefCache(HashMap<TileSpec, TileMetrics>);

impl SimCache for RefCache {
    fn simulate(&mut self, cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics {
        if let Some(m) = self.0.get(spec) {
            return *m;
        }
        let m = simulate_tile_reference(cfg, spec);
        self.0.insert(*spec, m);
        m
    }

    fn unique_tiles(&self) -> usize {
        self.0.len()
    }
}

fn main() {
    common::header("§Perf — whole-suite cold planning: reference walk vs fast path");
    let cfg = ChipConfig::voltra();
    let suite = evaluation_suite();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8);

    let walk_mapper = MapperCache::new();
    let walked = common::time(3, || {
        for w in &suite {
            let mut tiles = RefCache(HashMap::new());
            let mut mapper = IncrementalMapper::new(&walk_mapper);
            let mut layers = Vec::with_capacity(w.layers.len());
            for l in &w.layers {
                layers.push(planner::plan_layer_mapped(&cfg, l, &mut tiles, &mut mapper));
            }
            residency::apply(&cfg, &w.layers, &mut layers);
            std::hint::black_box(&layers);
        }
    });
    common::show("suite x8, cold plan (reference walk, seq)", 3, walked);

    let fast = common::time(5, || {
        for w in &suite {
            let tiles = SharedTileCache::new();
            std::hint::black_box(plan::build_parallel(&cfg, w, &tiles, threads));
        }
    });
    common::show(
        &format!("suite x8, cold plan (fast path, {threads} thr)"),
        5,
        fast,
    );

    common::rule();
    let (walk_mean, _, _) = walked;
    let (fast_mean, _, _) = fast;
    let speedup = walk_mean / fast_mean;
    println!(
        "cold suite planning is {speedup:.1}x faster on the shipped path \
         (steady-state fast path + {threads}-thread compile; floor 5x)"
    );
    assert!(
        speedup >= 5.0,
        "PR 6 acceptance: cold suite planning must be >= 5x faster than the \
         sequential reference walk (got {speedup:.2}x)"
    );
}
