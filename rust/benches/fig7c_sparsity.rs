//! Fig. 7c: energy efficiency vs weight sparsity and input toggle rate.
//!
//! Paper: efficiency rises with weight sparsity (zero weights clock-gate
//! the multipliers) and with lower input toggle rates, saturating as the
//! non-datapath energy floor (memory, control, leakage) dominates.

#[path = "common.rs"]
mod common;

use voltra::config::{ChipConfig, OperatingPoint};
use voltra::power::{tops_per_watt, Activity, EnergyParams};
use voltra::sim::{simulate_tile, TileSpec};

fn main() {
    common::header("Fig. 7c — effective TOPS/W vs weight sparsity x input toggle rate");
    let cfg = ChipConfig::voltra();
    let t = simulate_tile(&cfg, &TileSpec::simple(96, 96, 96));
    let p = EnergyParams::default();
    let op = OperatingPoint::efficiency();

    let toggles = [1.0, 0.75, 0.5, 0.25];
    let sparsities = [0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0];
    print!("{:>10}", "sparsity");
    for tr in toggles {
        print!("   TR={tr:>4.2}");
    }
    println!();
    common::rule();
    let mut base = 0.0;
    for s in sparsities {
        print!("{:>9.1}%", 100.0 * s);
        for tr in toggles {
            let eff = tops_per_watt(
                &p,
                &t,
                &Activity {
                    weight_sparsity: s,
                    input_toggle: tr,
                },
                op,
            );
            if s == 0.0 && tr == 1.0 {
                base = eff;
            }
            print!(" {eff:>9.3}");
        }
        println!();
    }
    common::rule();
    let top = tops_per_watt(
        &p,
        &t,
        &Activity {
            weight_sparsity: 1.0,
            input_toggle: 0.25,
        },
        op,
    );
    println!(
        "dense/TR=1.0 baseline {base:.2} TOPS/W -> fully sparse/quiet {top:.2} TOPS/W ({:.2}x, saturating)",
        top / base
    );

    common::report("fig7c sweep", 20, || {
        for s in sparsities {
            for tr in toggles {
                let _ = tops_per_watt(
                    &p,
                    &t,
                    &Activity {
                        weight_sparsity: s,
                        input_toggle: tr,
                    },
                    op,
                );
            }
        }
    });
}
