//! BERT multi-head attention on Voltra — the Fig. 4 walkthrough.
//!
//! 1. Functional: one BERT-Base head (token size 64) through the `mha64`
//!    artifact (the exact GEMM sequence the chip schedules, with the
//!    weight streamer's on-the-fly K^T transposer), checked against a
//!    host reference that replicates the int8 GEMM chain.
//! 2. PDMA walkthrough: the dynamic memory allocation timeline of
//!    Fig. 4b — which operand lives where in the shared memory at each
//!    step of the sequence — and the data-access saving vs a
//!    separated-memory architecture (Fig. 4c reports 14.3%).
//!
//! Run with: `cargo run --release --example bert_mha`

use voltra::runtime::{default_dir, ArtifactLib, MatI32};
use voltra::tiling::allocator::Footprint;
use voltra::tiling::place;
use voltra::config::MemoryOrg;

const T: usize = 64; // token size (Fig. 4a)
const D: usize = 768; // BERT-Base hidden
const DH: usize = 64; // head dim

struct Rng(u64);
impl Rng {
    fn next_i8(&mut self) -> i32 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        ((z ^ (z >> 31)) % 255) as i32 - 127
    }
    fn mat(&mut self, r: usize, c: usize) -> MatI32 {
        MatI32::from_fn(r, c, |_, _| self.next_i8())
    }
}

/// Host reference of the chip's MHA head (mirrors kernels/ref.py).
fn mha_ref(x: &MatI32, wq: &MatI32, wk: &MatI32, wv: &MatI32, s_qkv: f32, s_attn: f32) -> MatI32 {
    let proj = |w: &MatI32| -> MatI32 {
        let acc = voltra::runtime::gemm_ref(x, w, &MatI32::zeros(T, DH));
        voltra::runtime::requant_ref(&acc, s_qkv)
    };
    let (q, k, v) = (proj(wq), proj(wk), proj(wv));
    let kt = MatI32::from_fn(DH, T, |r, c| k.at(c, r));
    let s = voltra::runtime::gemm_ref(&q, &kt, &MatI32::zeros(T, T));
    // f32 softmax over scaled scores.
    let mut a8 = MatI32::zeros(T, T);
    let scale = 1.0 / (DH as f32).sqrt();
    for r in 0..T {
        let row: Vec<f32> = (0..T).map(|c| s.at(r, c) as f32 * scale).collect();
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = row.iter().map(|&v| (v - mx).exp()).collect();
        let sum: f32 = exps.iter().sum();
        for c in 0..T {
            let p = exps[c] / sum;
            a8.data[r * T + c] = (p * s_attn).round_ties_even().clamp(-128.0, 127.0) as i32;
        }
    }
    voltra::runtime::gemm_ref(&a8, &v, &MatI32::zeros(T, DH))
}

fn main() -> anyhow::Result<()> {
    println!("=== functional path: one BERT-Base MHA head on PJRT ===");
    let mut lib = ArtifactLib::load(default_dir())?;
    let mut rng = Rng(7);
    let x = rng.mat(T, D);
    let (wq, wk, wv) = (rng.mat(D, DH), rng.mat(D, DH), rng.mat(D, DH));
    let (s_qkv, s_attn) = (0.0005f32, 127.0f32);

    let to_lit = |m: &MatI32| -> anyhow::Result<xla::Literal> {
        Ok(xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
    };
    let outs = lib.run(
        "mha64",
        &[
            to_lit(&x)?,
            to_lit(&wq)?,
            to_lit(&wk)?,
            to_lit(&wv)?,
            xla::Literal::vec1(&[s_qkv]),
            xla::Literal::vec1(&[s_attn]),
        ],
    )?;
    let o = outs[0].to_vec::<i32>()?;
    let oref = mha_ref(&x, &wq, &wk, &wv, s_qkv, s_attn);

    // The integer GEMMs are exact; the f32 softmax may round one count
    // differently between XLA and the host — allow +-1 per attention
    // weight, i.e. a tiny bound on the int32 context accumulators.
    let max_a: i32 = 128;
    let mut worst = 0i64;
    for (got, want) in o.iter().zip(&oref.data) {
        worst = worst.max((*got as i64 - *want as i64).abs());
    }
    assert!(
        worst <= 2 * max_a as i64,
        "context accumulators differ by {worst} (allowed {})",
        2 * max_a
    );
    println!(
        "  mha64 on PJRT matches the host reference (max |Δacc| = {worst} ≤ {}) ✓",
        2 * max_a
    );

    println!("\n=== PDMA walkthrough: Fig. 4b allocation timeline ===");
    // The MHA sequence, with live operands at each step (bytes).
    // X (T x D) stays resident; Q/K/V/S/A/O come and go via base-pointer
    // updates — no inter-buffer copies, no off-chip round trips.
    let steps: [(&str, usize, usize, usize, usize); 5] = [
        // (step, input bytes, weight bytes, psum bytes, output bytes)
        ("Q = X Wq", T * D, D * DH, 4 * T * DH, T * DH),
        ("K = X Wk", T * D, D * DH, 4 * T * DH, T * DH),
        ("V = X Wv", T * D, D * DH, 4 * T * DH, T * DH),
        ("S = Q K^T (transposer)", T * DH + T * DH, 0, 4 * T * T, T * T),
        ("O = softmax(S) V", T * T + T * DH, 0, 4 * T * DH, T * DH),
    ];
    for (name, i, w, p, o) in steps {
        let fp = Footprint {
            input: i,
            weight: w,
            psum: p,
            output: o,
        };
        let pl = place(&MemoryOrg::Shared, &fp).unwrap();
        println!(
            "  {name:<26} in@w{:<5} wt@w{:<5} psum@w{:<5} out@w{:<5} ({} KiB live)",
            pl.input_base,
            pl.weight_base,
            pl.psum_base,
            pl.output_base,
            fp.total() / 1024
        );
    }

    // Fig. 4c: access counting. Shared: every operand written once by its
    // producer and read once by its consumer, in place. Separated: Q, K,
    // V, S, A must additionally round-trip between the output buffer and
    // the input buffer (via off-chip memory, Fig. 4c).
    let x_b = T * D;
    let w_b = 3 * D * DH;
    let qkv = 3 * T * DH;
    let s_b = T * T;
    let o_b = T * DH;
    let a_b = T * T; // the softmax'ed attention matrix A
    let shared_access = x_b * 3 + w_b + qkv * 2 + s_b * 2 + a_b * 2 + o_b;
    let roundtrip = qkv + s_b + a_b; // intermediates copied out+in again
    let separated_access = shared_access + 2 * roundtrip;
    let saved = 1.0 - shared_access as f64 / separated_access as f64;
    println!(
        "\n  data access count: shared {} vs separated {}  ->  {:.1}% saved (paper: 14.3%)",
        shared_access,
        separated_access,
        100.0 * saved
    );
    println!("\nbert_mha OK");
    Ok(())
}
