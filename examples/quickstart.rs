//! Quickstart: the whole stack in one page.
//!
//! 1. load the AOT artifacts (HLO text compiled by `make artifacts`);
//! 2. run one chip-native 8x8x8 GEMM tile + requant on the PJRT runtime
//!    and check it against the host oracle;
//! 3. cycle-simulate the same tile on the chip model and print the
//!    utilization / energy the chip would achieve.
//!
//! Run with: `cargo run --release --example quickstart`

use voltra::config::{ChipConfig, OperatingPoint};
use voltra::power::{power_mw, tops_per_watt, Activity, EnergyParams};
use voltra::runtime::{default_dir, ArtifactLib, MatI32};
use voltra::sim::{simulate_tile, TileSpec};

fn main() -> anyhow::Result<()> {
    // ---------------------------------------------------------- runtime
    let dir = default_dir();
    let mut lib = ArtifactLib::load(&dir)?;
    println!("loaded {} artifacts from {}", lib.names().len(), dir.display());

    // One chip tile: x, w int8-range, psum int32, through `gemm8`.
    let x = MatI32::from_fn(8, 8, |r, c| (r * 8 + c) as i32 % 17 - 8);
    let w = MatI32::from_fn(8, 8, |r, c| (r as i32 - c as i32) * 3 % 11);
    let p = MatI32::from_fn(8, 8, |r, c| (r + c) as i32 * 100);
    let scale = xla::Literal::vec1(&[0.01f32]);
    let outs = lib.run(
        "gemm8",
        &[
            xla::Literal::vec1(&x.data).reshape(&[8, 8])?,
            xla::Literal::vec1(&w.data).reshape(&[8, 8])?,
            xla::Literal::vec1(&p.data).reshape(&[8, 8])?,
            scale,
        ],
    )?;
    let acc = outs[1].to_vec::<i32>()?;
    let expect = voltra::runtime::gemm_ref(&x, &w, &p);
    assert_eq!(acc, expect.data, "PJRT tile does not match the host oracle");
    println!("gemm8 on PJRT matches the host int32 oracle ✓");
    let q = outs[0].to_vec::<i32>()?;
    assert!(q.iter().all(|&v| (-128..=127).contains(&v)));
    println!("requant output stays in int8 range ✓  (first row: {:?})", &q[..8]);

    // --------------------------------------------------------- simulator
    let cfg = ChipConfig::voltra();
    let tile = TileSpec::simple(64, 512, 64);
    let m = simulate_tile(&cfg, &tile);
    println!(
        "\ncycle model, 64x512x64 tile: {} cycles, {:.1}% temporal, {:.1}% spatial",
        m.total_cycles,
        100.0 * m.temporal_utilization(),
        100.0 * m.spatial_utilization()
    );
    let params = EnergyParams::default();
    let act = Activity::default();
    for op in [OperatingPoint::efficiency(), OperatingPoint::performance()] {
        println!(
            "  @{:.1}V/{:.0}MHz: {:>6.1} mW, {:.2} TOPS/W",
            op.voltage,
            op.freq_mhz,
            power_mw(&params, &m, &act, op),
            tops_per_watt(&params, &m, &act, op)
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
