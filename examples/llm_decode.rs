//! LLM decode stress test: the worst-case utilization workload of Fig. 6.
//!
//! Decode is dominated by skinny GEMMs (batch-6 projections, M=1
//! per-sequence attention against the KV cache) — exactly the shape
//! mismatch the 3D array was built to soften. This example:
//!   1. runs the LLaMA3.2-3B decode step through the chip model on all
//!      four configurations and prints the utilization/latency ladder;
//!   2. executes a real batch-6 GEMV bundle (the q-projection slice) on
//!      the PJRT runtime, verified against the host oracle, and reports
//!      the achieved tokens/s implied by the cycle model.
//!
//! Run with: `cargo run --release --example llm_decode`

use voltra::config::ChipConfig;
use voltra::coordinator::run_workload;
use voltra::power::energy::workload_energy_j;
use voltra::power::{Activity, EnergyParams};
use voltra::runtime::{default_dir, gemm_ref, gemm_tiled, ArtifactLib, MatI32};
use voltra::workloads::transformers::llama_decode;

fn main() -> anyhow::Result<()> {
    let w = llama_decode(256, 6);
    println!("=== chip-model ladder: {} (batch 6, context 256) ===", w.name);
    let configs: [(&str, ChipConfig); 4] = [
        ("voltra (3D+MGDP+PDMA)", ChipConfig::voltra()),
        ("2D array baseline", ChipConfig::array2d()),
        ("no prefetch", ChipConfig::no_prefetch()),
        ("separated memory", ChipConfig::separated_memory()),
    ];
    let mut voltra_latency = 0u64;
    for (name, cfg) in &configs {
        let r = run_workload(cfg, &w);
        let m = &r.metrics;
        if *name == "voltra (3D+MGDP+PDMA)" {
            voltra_latency = m.total_latency_cycles();
        }
        let e = workload_energy_j(
            &EnergyParams::default(),
            m,
            &Activity::default(),
            cfg.operating_point,
        );
        println!(
            "  {name:<24} spatial {:>6.2}%  temporal {:>6.2}%  latency {:>11} cyc  energy {:>8.2} mJ",
            100.0 * m.spatial_utilization(),
            100.0 * m.temporal_utilization(),
            m.total_latency_cycles(),
            e * 1e3
        );
    }
    let cfg = ChipConfig::voltra();
    let tok_s = cfg.operating_point.freq_mhz * 1e6 / voltra_latency as f64;
    println!(
        "  -> one decode step = {:.2} ms @800MHz = {:.2} tokens/s/stream x 6 streams",
        voltra_latency as f64 / (cfg.operating_point.freq_mhz * 1e3),
        tok_s
    );

    println!("\n=== batch sweep: the GEMV utilization cliff ===");
    println!("  {:>6} {:>10} {:>10} {:>12}", "batch", "3D array", "2D array", "3D/2D");
    for b in [1u64, 2, 4, 6, 8, 12, 16] {
        let wl = llama_decode(256, b);
        let s3 = run_workload(&ChipConfig::voltra(), &wl)
            .metrics
            .spatial_utilization();
        let s2 = run_workload(&ChipConfig::array2d(), &wl)
            .metrics
            .spatial_utilization();
        println!(
            "  {b:>6} {:>9.2}% {:>9.2}% {:>11.2}x",
            100.0 * s3,
            100.0 * s2,
            s3 / s2
        );
    }
    println!("  -> single-stream decode (batch 1) is pure GEMV: both arrays crater;");
    println!("     the 3D array recovers by batch 8 (its M-axis is 8), the 2D needs 16.");

    println!("\n=== functional path: batch-6 projection GEMV bundle on PJRT ===");
    let mut lib = ArtifactLib::load(default_dir())?;
    // A slice of the q-projection: (6 x 3072) x (3072 x 128) for one head.
    let x = MatI32::from_fn(6, 3072, |r, c| ((r * 31 + c * 7) % 255) as i32 - 127);
    let wt = MatI32::from_fn(3072, 128, |r, c| ((r * 13 + c * 17) % 255) as i32 - 127);
    let p = MatI32::zeros(6, 128);
    let t0 = std::time::Instant::now();
    let (_q, acc) = gemm_tiled(&mut lib, &x, &wt, &p, 0.0002)?;
    let dt = t0.elapsed();
    assert_eq!(acc, gemm_ref(&x, &wt, &p), "PJRT GEMV bundle mismatch");
    println!(
        "  (6x3072)x(3072x128) verified exact in {:.1} ms ({} tile calls) ✓",
        dt.as_secs_f64() * 1e3,
        1 * 48 * 2
    );
    println!("\nllm_decode OK");
    Ok(())
}
