//! END-TO-END DRIVER: a real ResNet-50 workload through every layer of
//! the stack.
//!
//! Functional path (real numerics, Rust + PJRT only — Python never runs):
//!   a ResNet conv2_x bottleneck block (1x1 -> 3x3 -> 1x1 + projection)
//!   at 56x56x64, INT8 inference with implicit-im2col GEMMs dispatched
//!   tile-by-tile to the `gemm64` artifact, fused requantization, and a
//!   maxpool stage — every layer verified bit-exactly against the host
//!   int32 oracle.
//!
//! Timing/energy path: the *full* ResNet-50 through the cycle-accurate
//! chip model, reporting the Fig. 6 metrics and the energy model's
//! per-inference cost. Results are recorded in EXPERIMENTS.md.
//!
//! Run with: `cargo run --release --example resnet50_e2e`

use std::time::Instant;

use voltra::config::ChipConfig;
use voltra::coordinator::run_workload;
use voltra::power::energy::workload_energy_j;
use voltra::power::{Activity, EnergyParams};
use voltra::runtime::{default_dir, gemm_ref, gemm_tiled, requant_ref, ArtifactLib, MatI32};
use voltra::sim::maxpool::maxpool_hwc;
use voltra::workloads::resnet50::resnet50;

/// Host-side implicit im2col: NHWC (batch 1) -> patch matrix, SAME pad.
fn im2col(x: &[i32], h: usize, w: usize, c: usize, k: usize, stride: usize) -> (MatI32, usize, usize) {
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let pad = (k - 1) / 2;
    let mut m = MatI32::zeros(oh * ow, k * k * c);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            let mut col = 0;
            for dy in 0..k {
                for dx in 0..k {
                    let iy = (oy * stride + dy) as isize - pad as isize;
                    let ix = (ox * stride + dx) as isize - pad as isize;
                    for ch in 0..c {
                        let v = if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                            x[(iy as usize * w + ix as usize) * c + ch]
                        } else {
                            0
                        };
                        m.data[row * (k * k * c) + col] = v;
                        col += 1;
                    }
                }
            }
        }
    }
    (m, oh, ow)
}

struct Rng(u64);
impl Rng {
    fn next_i8(&mut self) -> i32 {
        // splitmix64, mapped to int8 range.
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        ((z ^ (z >> 31)) % 255) as i32 - 127
    }
    fn mat(&mut self, r: usize, c: usize) -> MatI32 {
        MatI32::from_fn(r, c, |_, _| self.next_i8())
    }
}

/// One conv layer on the PJRT runtime, checked against the host oracle.
fn conv_layer(
    lib: &mut ArtifactLib,
    name: &str,
    x: &[i32],
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    wts: &MatI32,
    scale: f32,
) -> anyhow::Result<(Vec<i32>, usize, usize)> {
    let t0 = Instant::now();
    let (patches, oh, ow) = im2col(x, h, w, cin, k, stride);
    let psum = MatI32::zeros(patches.rows, cout);
    let (q, acc) = gemm_tiled(lib, &patches, wts, &psum, scale)?;
    // Bit-exact verification against the host int32 oracle.
    let acc_ref = gemm_ref(&patches, wts, &psum);
    assert_eq!(acc, acc_ref, "{name}: PJRT accumulator mismatch");
    let q_ref = requant_ref(&acc_ref, scale);
    assert_eq!(q, q_ref, "{name}: PJRT requant mismatch");
    println!(
        "  {name:<12} {h}x{w}x{cin} -> {oh}x{ow}x{cout}  ({} tile GEMM calls, {:.2}s, verified exact ✓)",
        patches.rows.div_ceil(64) * cout.div_ceil(64) * patches.cols.div_ceil(64),
        t0.elapsed().as_secs_f32(),
    );
    Ok((q.data, oh, ow))
}

fn main() -> anyhow::Result<()> {
    println!("=== functional path: ResNet conv2_x bottleneck on PJRT ===");
    let mut lib = ArtifactLib::load(default_dir())?;
    let mut rng = Rng(42);
    let (h, w, c) = (56usize, 56usize, 64usize);
    let x0: Vec<i32> = (0..h * w * c).map(|_| rng.next_i8()).collect();

    // Bottleneck: 1x1 reduce (64), 3x3 (64), 1x1 expand (256) + projection.
    let w1 = rng.mat(64, 64);
    let w2 = rng.mat(9 * 64, 64);
    let w3 = rng.mat(64, 256);
    let wproj = rng.mat(64, 256);
    let s = 0.004f32;

    let (y1, h1, w1d) = conv_layer(&mut lib, "conv1x1a", &x0, h, w, c, 64, 1, 1, &w1, s)?;
    let (y2, h2, w2d) = conv_layer(&mut lib, "conv3x3", &y1, h1, w1d, 64, 64, 3, 1, &w2, s)?;
    let (y3, ..) = conv_layer(&mut lib, "conv1x1b", &y2, h2, w2d, 64, 256, 1, 1, &w3, s)?;
    let (yproj, ..) = conv_layer(&mut lib, "proj", &x0, h, w, c, 256, 1, 1, &wproj, s)?;

    // Residual add + ReLU through the chip's fused SIMD path: the
    // `residual64` artifact processes 64x64 tiles of the (HW, C) view,
    // verified against the host oracle.
    let t0 = Instant::now();
    let rows = h * w; // 3136
    let cols = 256usize;
    let mut y = vec![0i32; rows * cols];
    let one = xla::Literal::vec1(&[1.0f32]);
    let mut calls = 0;
    for r0 in (0..rows).step_by(64) {
        for c0 in (0..cols).step_by(64) {
            let mut ta = vec![0i32; 64 * 64];
            let mut tb = vec![0i32; 64 * 64];
            for r in 0..64 {
                for c in 0..64 {
                    ta[r * 64 + c] = y3[(r0 + r) * cols + c0 + c];
                    tb[r * 64 + c] = yproj[(r0 + r) * cols + c0 + c];
                }
            }
            let outs = lib.run(
                "residual64",
                &[
                    xla::Literal::vec1(&ta).reshape(&[64, 64])?,
                    xla::Literal::vec1(&tb).reshape(&[64, 64])?,
                    one.clone(),
                ],
            )?;
            let q = outs[0].to_vec::<i32>()?;
            for r in 0..64 {
                for c in 0..64 {
                    y[(r0 + r) * cols + c0 + c] = q[r * 64 + c];
                }
            }
            calls += 1;
        }
    }
    // Host oracle: q8(relu(a + b)).
    for (i, (&a, &b)) in y3.iter().zip(&yproj).enumerate() {
        let expect = ((a + b).max(0)).min(127);
        assert_eq!(y[i], expect, "residual mismatch at {i}");
    }
    println!(
        "  residual     fused add+ReLU+requant on SIMD path ({calls} tile calls, {:.2}s, verified exact ✓)",
        t0.elapsed().as_secs_f32()
    );

    // Maxpool 2x2 through the maxpool-unit model (exact path).
    let y_i8: Vec<i8> = y.iter().map(|&v| v as i8).collect();
    let (pooled, ph, pw) = maxpool_hwc(&y_i8, h, w, 256, 2, 2);
    println!("  maxpool      {h}x{w}x256 -> {ph}x{pw}x256 ✓");

    // Classifier head via the tiled GEMM (M = 1 GEMV).
    let feat: Vec<i32> = pooled[..256].iter().map(|&v| v as i32).collect();
    let head_w = rng.mat(256, 10);
    let feat_m = MatI32 {
        rows: 1,
        cols: 256,
        data: feat,
    };
    let (logits_q, logits) = gemm_tiled(&mut lib, &feat_m, &head_w, &MatI32::zeros(1, 10), 0.001)?;
    assert_eq!(logits, gemm_ref(&feat_m, &head_w, &MatI32::zeros(1, 10)));
    println!("  head         1x256 -> 1x10 logits (verified ✓): {:?}", &logits_q.data);

    println!("\n=== timing/energy path: full ResNet-50 on the chip model ===");
    let net = resnet50();
    let cfg = ChipConfig::voltra();
    let t0 = Instant::now();
    let r = run_workload(&cfg, &net);
    let m = &r.metrics;
    let e = workload_energy_j(
        &EnergyParams::default(),
        m,
        &Activity::default(),
        cfg.operating_point,
    );
    let secs = m.total_latency_cycles() as f64 / (cfg.operating_point.freq_mhz * 1e6);
    println!(
        "  {} layers, {:.2} GMACs | spatial {:.2}%, temporal {:.2}%",
        net.layers.len(),
        net.total_macs() as f64 / 1e9,
        100.0 * m.spatial_utilization(),
        100.0 * m.temporal_utilization()
    );
    println!(
        "  latency {} cycles = {:.2} ms @800MHz | energy {:.2} mJ | {:.1} fps ({} unique tiles simulated in {:.2}s)",
        m.total_latency_cycles(),
        secs * 1e3,
        e * 1e3,
        1.0 / secs,
        r.unique_tiles,
        t0.elapsed().as_secs_f32(),
    );
    println!("\nresnet50_e2e OK");
    Ok(())
}
